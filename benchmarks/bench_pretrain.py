"""Paper Table 2 / Fig. 8 — pretraining: end-to-end time + perplexity,
BLaST vs dense, on the synthetic corpus (OpenWebText stand-in).

``--chaos-only`` runs the training chaos scenarios instead (ISSUE 8):
SIGKILL-and-resume recovery latency + bitwise parity, NaN-skip parity,
and corrupt-checkpoint fallback — results land in a JSON artifact
(``--out``, default BENCH_train_chaos.json) BEFORE any assertion, so a
failed oracle still leaves the measurements on disk for CI.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import signal
import tempfile
import time

import numpy as np

from benchmarks.common import (bench_cfg, replace_blast, row,
                               write_bench_artifact)
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.training import train_loop
from repro.training import faults as tf


def run(cfg, steps=60, seed=3):
    src = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=16,
                      seed=seed)
    opt = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=5,
                            total_steps=steps, weight_decay=0.01)
    loop = train_loop.TrainLoopConfig(total_steps=steps, log_every=steps)
    t0 = time.monotonic()
    state, hist = train_loop.train(cfg, opt, src, loop,
                                   log_fn=lambda m: None)
    wall = time.monotonic() - t0
    # eval perplexity on held-out batches
    import jax, jax.numpy as jnp
    from repro.core.distill import cross_entropy
    from repro.models import registry
    losses = []
    for i in range(3):
        b = src.batch(10_000 + i)
        logits, _ = registry.forward(cfg, state.params,
                                     jnp.asarray(b["tokens"]),
                                     masks=state.masks or None)
        losses.append(float(cross_entropy(logits,
                                          jnp.asarray(b["labels"]))))
    ppl = math.exp(np.mean(losses))
    return wall, ppl, hist[-1]["sparsity"]


def main():
    steps = 60
    dense = bench_cfg()
    dense = replace_blast(dense, enabled=False)
    tw, ppl, _ = run(dense, steps)
    row("pretrain_dense", tw * 1e6 / steps, f"ppl={ppl:.2f}")
    for s_max, d in ((0.7, 0), (0.8, 20)):
        cfg = bench_cfg()
        cfg = replace_blast(cfg, s_max=s_max, decay=d, total_steps=steps)
        tw, ppl, sp = run(cfg, steps)
        row(f"pretrain_blast_s{int(s_max*100)}_d{d}",
            tw * 1e6 / steps,
            f"ppl={ppl:.2f} sparsity={sp:.2f}")


# ------------------------------------------------------- chaos scenarios
def _chaos_cfg():
    from repro.configs.base import ModelConfig
    from repro.core.prune_grow import BlastSpec
    spec = tf.default_chaos_spec(".")
    return ModelConfig(**spec["model"], blast=BlastSpec(**spec["blast"]))


def _chaos_train(cfg, steps, faults=None, **loop_kw):
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=3)
    opt = adamw.AdamWConfig(peak_lr=2e-2, warmup_steps=5, total_steps=60,
                            weight_decay=0.0)
    loop = train_loop.TrainLoopConfig(total_steps=steps,
                                      log_every=10 ** 9, **loop_kw)
    return train_loop.train(cfg, opt, src, loop, faults=faults,
                            log_fn=lambda m: None)


def _leaves(state):
    import jax
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(
        {"step": state.step, "params": state.params,
         "opt_state": state.opt_state, "masks": state.masks,
         "rng": state.rng})]


def _bitwise(a_leaves, b_leaves):
    return all(np.array_equal(a, b)
               for a, b in zip(a_leaves, b_leaves))


def _scenario_sigkill(wd):
    """Kill a subprocess run at step 11 (newest ckpt: step 8), resume,
    compare bitwise with an uninterrupted run; measure recovery."""
    ck = os.path.join(wd, "ck")
    spec_a = tf.default_chaos_spec(wd, ckpt_dir=ck, kill_at=11)
    ra = tf.run_child(spec_a, os.path.join(wd, "a.json"))
    spec_a2 = tf.default_chaos_spec(wd, ckpt_dir=ck)
    ra2 = tf.run_child(spec_a2, os.path.join(wd, "a2.json"))
    spec_b = tf.default_chaos_spec(
        wd, out=os.path.join(wd, "final_b.npz"),
        meta_out=os.path.join(wd, "meta_b.json"))
    rb = tf.run_child(spec_b, os.path.join(wd, "b.json"))
    meta = {}
    if ra2.returncode == 0:
        with open(spec_a2["meta_out"]) as f:
            meta = json.load(f)
    bitwise = False
    if ra2.returncode == 0 and rb.returncode == 0:
        with np.load(spec_a2["out"]) as za, np.load(spec_b["out"]) as zb:
            bitwise = (set(za.files) == set(zb.files)
                       and all(np.array_equal(za[k], zb[k])
                               for k in za.files))
    resumed = meta.get("resumed_from")
    return {
        "scenario": "sigkill_resume",
        "killed": ra.returncode == -signal.SIGKILL,
        "kill_at": spec_a["kill_at"],
        "resumed_from": resumed,
        "steps_lost": (spec_a["kill_at"] - resumed
                       if resumed is not None else None),
        "recovery_wall_s": meta.get("wall_s"),
        "verify_latency_s": meta.get("verify_latency_s"),
        "bitwise_identical": bitwise,
    }


def _scenario_nan_skip():
    """NaN grads at two steps under skip policy vs never applying those
    updates: final TrainStates must match bitwise."""
    cfg = _chaos_cfg()
    plan_a = tf.TrainFaultPlan().nan_grads(5).nan_grads(9)
    t0 = time.monotonic()
    state_a, hist_a = _chaos_train(cfg, 16, faults=plan_a)
    wall = time.monotonic() - t0
    plan_b = tf.TrainFaultPlan().force_skip(5).force_skip(9)
    state_b, _ = _chaos_train(cfg, 16, faults=plan_b)
    m = [h for h in hist_a if "event" not in h][-1]
    return {
        "scenario": "nan_skip_parity",
        "injected": 2,
        "skipped_steps": m["skipped_steps"],
        "wall_s": wall,
        "bitwise_identical": _bitwise(_leaves(state_a),
                                      _leaves(state_b)),
    }


def _scenario_corrupt_fallback(wd):
    """The fault plan bit-flips the newest checkpoint after it lands;
    resume must fall back to the previous intact one and still converge
    to the clean run bitwise."""
    cfg = _chaos_cfg()
    d = os.path.join(wd, "ck")
    plan = tf.TrainFaultPlan().corrupt_checkpoint(2)   # step-12 save
    _chaos_train(cfg, 12, faults=plan, ckpt_dir=d, ckpt_every=4)
    t0 = time.monotonic()
    state_a, hist = _chaos_train(cfg, 20, ckpt_dir=d, ckpt_every=4)
    wall = time.monotonic() - t0
    state_c, _ = _chaos_train(cfg, 20)
    m = [h for h in hist if "event" not in h][-1]
    return {
        "scenario": "corrupt_ckpt_fallback",
        "corrupted_saves": len(plan.fired),
        "ckpt_fallbacks": m["ckpt_fallbacks"],
        "resume_wall_s": wall,
        "bitwise_identical": _bitwise(_leaves(state_a),
                                      _leaves(state_c)),
    }


def chaos_main(out: str):
    rows = []
    with tempfile.TemporaryDirectory() as wd:
        rows.append(_scenario_sigkill(wd))
    rows.append(_scenario_nan_skip())
    with tempfile.TemporaryDirectory() as wd:
        rows.append(_scenario_corrupt_fallback(wd))
    write_bench_artifact(out, "train_chaos", rows)  # BEFORE any assert
    for r in rows:
        row(f"chaos_{r['scenario']}", 0.0,
            f"bitwise={r['bitwise_identical']}")
    assert all(r["bitwise_identical"] for r in rows), rows
    assert rows[0]["killed"] and rows[0]["resumed_from"] == 8
    assert rows[1]["skipped_steps"] == 2
    assert rows[2]["ckpt_fallbacks"] >= 1
    print(f"chaos scenarios OK -> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos-only", action="store_true")
    ap.add_argument("--out", default="BENCH_train_chaos.json")
    args = ap.parse_args()
    if args.chaos_only:
        chaos_main(args.out)
    else:
        main()
