"""Paper Table 1 analogue — accuracy RECOVERY when sparsifying a dense
pretrained model (the fine-tuning setting, §5.2): pretrain dense, then
iteratively sparsify while training (with and without distillation) and
report the held-out perplexity gap vs the dense model."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, replace_blast, row
from repro.core.distill import cross_entropy
from repro.data.pipeline import SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.training import train_loop


def _ppl(cfg, state, src):
    losses = []
    for i in range(3):
        b = src.batch(20_000 + i)
        logits, _ = registry.forward(cfg, state.params,
                                     jnp.asarray(b["tokens"]),
                                     masks=state.masks or None)
        losses.append(float(cross_entropy(logits,
                                          jnp.asarray(b["labels"]))))
    return math.exp(np.mean(losses))


def main():
    steps_pre, steps_ft = 80, 50
    dense = replace_blast(bench_cfg(), enabled=False)
    src = SyntheticLM(dense.vocab_size, seq_len=64, global_batch=16,
                      seed=5)
    opt = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=5,
                            total_steps=steps_pre, weight_decay=0.01)
    loop = train_loop.TrainLoopConfig(total_steps=steps_pre,
                                      log_every=steps_pre)
    tstate, _ = train_loop.train(dense, opt, src, loop,
                                 log_fn=lambda m: None)
    ppl_dense = _ppl(dense, tstate, src)
    row("tbl1_dense", 0.0, f"ppl={ppl_dense:.2f}")

    for s_max, b in ((0.7, 32), (0.9, 32), (0.7, 16)):
        for kd in (0.0, 0.5):
            cfg = replace_blast(bench_cfg(), s_max=s_max, b_in=b,
                                b_out=b, total_steps=steps_ft,
                                step_size=5)
            import dataclasses
            from repro.training import step as ts
            state = ts.init_state(cfg, jax.random.PRNGKey(0))
            # init student from the dense pretrained weights (§5.2);
            # COPY: the train step donates its input buffers
            state = dataclasses.replace(
                state, params=jax.tree_util.tree_map(jnp.copy,
                                                     tstate.params))
            opt_ft = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=5,
                                       total_steps=steps_ft,
                                       weight_decay=0.01)
            loop_ft = train_loop.TrainLoopConfig(total_steps=steps_ft,
                                                 log_every=steps_ft)
            state, _ = train_loop.train(
                cfg, opt_ft, src, loop_ft, state=state,
                log_fn=lambda m: None,
                teacher_params=tstate.params if kd else None,
                teacher_cfg=dense if kd else None, kd_beta=kd)
            ppl = _ppl(cfg, state, src)
            row(f"tbl1_blast_s{int(s_max*100)}_b{b}_kd{kd}", 0.0,
                f"ppl={ppl:.2f} gap={(ppl - ppl_dense):.2f}")


if __name__ == "__main__":
    main()
