"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (task spec).

  bench_bspmm      Fig. 4  kernel speedup vs sparsity/block
  bench_mlp_llama  Fig. 5  Llama-family MLP speedup + Fig. 7 memory/GPUs
  bench_inference  Fig. 6  end-to-end decode speedup
  bench_pretrain   Tbl. 2  pretrain wall-time + perplexity
  bench_finetune   Tbl. 1  accuracy recovery (+distillation)
  bench_ablations  Tbl. 4/5/6, Fig. 11, selection-mode ablation
  bench_regrowth   Fig. 10 regrown-block ratio
"""
from __future__ import annotations

import sys
import time

from benchmarks import (bench_ablations, bench_bspmm, bench_finetune,
                        bench_inference, bench_mlp_llama, bench_pretrain,
                        bench_regrowth)

ALL = {
    "bspmm": bench_bspmm.main,
    "mlp_llama": bench_mlp_llama.main,
    "inference": bench_inference.main,
    "pretrain": bench_pretrain.main,
    "finetune": bench_finetune.main,
    "ablations": bench_ablations.main,
    "regrowth": bench_regrowth.main,
}


def main() -> None:
    only = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in only:
        t0 = time.time()
        ALL[name]()
        print(f"# {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
