"""Batched sparse serving (paper Fig. 6 setting): one-shot magnitude
sparsification of an assigned architecture's smoke config, then batched
greedy decoding through the packed BSpMM path vs the dense baseline.

    PYTHONPATH=src python examples/serve_sparse.py --arch stablelm-3b
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import sparse_mlp as sm
from repro.core.prune_grow import initial_mask
from repro.models import registry
from repro.serving import export, serve_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--sparsity", type=float, default=0.9)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = {}
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dataclasses.replace(cfg.blast, b_in=bi, b_out=bo,
                                    s_init=args.sparsity,
                                    s_max=args.sparsity)
        fn = lambda wi: initial_mask(pspec, wi)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        masks[path] = fn(w)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, 8)), jnp.int32)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, 16, cfg.d_model)) * 0.02,
            jnp.float32)

    dense = export.prune_params(cfg, params, {}, dtype=jnp.float32)
    t1, s1 = serve_loop.generate(cfg, dense, prompts,
                                 max_new_tokens=args.new_tokens, **kw)
    packed = export.pack_params(cfg, params, masks, dtype=jnp.float32)
    t2, s2 = serve_loop.generate(cfg, packed, prompts,
                                 max_new_tokens=args.new_tokens, **kw)
    md = export.memory_report(cfg, dense)
    mp = export.memory_report(cfg, packed)
    print(f"dense : {s1['tok_per_s']:.1f} tok/s, {md['bytes']:,} B")
    print(f"packed: {s2['tok_per_s']:.1f} tok/s, {mp['bytes']:,} B "
          f"({md['bytes'] / mp['bytes']:.2f}x smaller at "
          f"{args.sparsity:.0%} sparsity)")


if __name__ == "__main__":
    main()
