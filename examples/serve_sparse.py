"""Batched sparse serving (paper Fig. 6 setting): one-shot magnitude
sparsification of an assigned architecture's smoke config, then greedy
decoding through the continuous-batching engine (packed BSpMM path vs
the dense baseline). KV-cache-less families (ssm / hybrid / audio) fall
back to the token-by-token ``serve_loop`` oracle.

    PYTHONPATH=src python examples/serve_sparse.py --arch stablelm-3b \
        [--ragged] [--max-batch 2]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import sparse_mlp as sm
from repro.core.prune_grow import initial_mask
from repro.models import registry
from repro.serving import engine, export, serve_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--sparsity", type=float, default=0.9)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine lanes (default: --batch)")
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch")
    ap.add_argument("--slab-k", type=int, default=8,
                    help="decode steps per jitted slab (1 = per-token)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests "
                         "through the radix-tree prefix cache")
    ap.add_argument("--mixed", action="store_true",
                    help="stall-free mixed batching: fuse chunked "
                         "prefill into the decode step under the "
                         "prefill token budget")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = {}
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dataclasses.replace(cfg.blast, b_in=bi, b_out=bo,
                                    s_init=args.sparsity,
                                    s_max=args.sparsity)
        fn = lambda wi: initial_mask(pspec, wi)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        masks[path] = fn(w)

    rng = np.random.default_rng(0)
    use_engine = registry.supports_prefill_chunk(cfg)
    if use_engine:
        lens = (rng.integers(4, 9, size=args.batch) if args.ragged
                else [8] * args.batch)
        prompts = [rng.integers(0, cfg.vocab_size, size=(int(p),))
                   .astype(np.int32) for p in lens]
        def run(p):
            return engine.generate(cfg, p, prompts,
                                   max_new_tokens=args.new_tokens,
                                   max_batch=args.max_batch or args.batch,
                                   slab_k=args.slab_k,
                                   prefix_cache=args.prefix_cache,
                                   mixed=args.mixed)
    else:
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, 8)), jnp.int32)
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, 16, cfg.d_model)) * 0.02,
                jnp.float32)
        def run(p):
            return serve_loop.generate(cfg, p, prompts,
                                       max_new_tokens=args.new_tokens,
                                       **kw)

    dense = export.prune_params(cfg, params, {}, dtype=jnp.float32)
    t1, s1 = run(dense)
    packed = export.pack_params(cfg, params, masks, dtype=jnp.float32)
    t2, s2 = run(packed)
    md = export.memory_report(cfg, dense)
    mp = export.memory_report(cfg, packed)
    print(f"dense : {s1['tok_per_s']:.1f} tok/s, {md['bytes']:,} B")
    print(f"packed: {s2['tok_per_s']:.1f} tok/s, {mp['bytes']:,} B "
          f"({md['bytes'] / mp['bytes']:.2f}x smaller at "
          f"{args.sparsity:.0%} sparsity)")


if __name__ == "__main__":
    main()
