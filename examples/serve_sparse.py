"""Batched sparse serving (paper Fig. 6 setting): one-shot magnitude
sparsification of an assigned architecture's smoke config, then greedy
decoding through the continuous-batching engine (packed BSpMM path vs
the dense baseline). KV-cache-less families (ssm / hybrid / audio) fall
back to the token-by-token ``serve_loop`` oracle.

    PYTHONPATH=src python examples/serve_sparse.py --arch stablelm-3b \
        [--ragged] [--max-batch 2]

``--frontdoor`` serves the PACKED model through the asyncio front door
instead (production API): SLA priority classes + preemption with host
KV offload, interactive requests streaming in over a saturated batch
tier — prints the per-class TTFT split and the offload counters (see
launch/serve.py for the full launcher).
"""
import argparse
import asyncio
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import sparse_mlp as sm
from repro.core.prune_grow import initial_mask
from repro.models import registry
from repro.serving import engine, export, serve_loop
from repro.serving.frontend import AsyncEngine
from repro.serving.scheduler import BATCH, INTERACTIVE, SLAScheduler


def frontdoor(cfg, packed, args):
    """Serve the packed model behind the async production API: batch
    jobs saturate the lanes, interactive requests arrive live and jump
    the queue (preempting a batch lane's KV to host when the page pool
    is the bottleneck)."""
    rng = np.random.default_rng(0)
    sched = SLAScheduler(args.max_batch or 2, 96, aging_s=30.0)
    eng = engine.Engine(cfg, packed, max_batch=args.max_batch or 2,
                        max_len=96, slab_k=args.slab_k, page_size=8,
                        scheduler=sched, preempt=True)
    # jit-warm outside the served trace
    eng.submit(np.ones(16, np.int32), 4, priority=BATCH)
    eng.submit(np.ones(6, np.int32), 4, priority=INTERACTIVE)
    eng.run()
    eng.reset_stats()

    lat = {BATCH: [], INTERACTIVE: []}

    async def one(front, prompt, tokens, klass, *, delay=0.0, **kw):
        # TTFT from BEFORE the submit: ack latency and queue wait both
        # count, as a served client would experience them
        await asyncio.sleep(delay)
        t0 = time.monotonic()
        stream = await front.submit_async(prompt, tokens, priority=klass,
                                          **kw)
        async for _ in stream:
            lat[klass].append(time.monotonic() - t0)
            break
        await stream.result()

    async def run():
        async with AsyncEngine(eng) as front:
            tasks = [one(front,
                         rng.integers(0, cfg.vocab_size, 24)
                         .astype(np.int32),
                         args.new_tokens, BATCH) for _ in range(4)]
            tasks += [one(front,
                          rng.integers(0, cfg.vocab_size, 8)
                          .astype(np.int32),
                          8, INTERACTIVE, delay=(k + 1) * 0.5,
                          deadline_s=0.5) for k in range(6)]
            await asyncio.gather(*tasks)

    asyncio.run(run())
    for name, klass in (("interactive", INTERACTIVE), ("batch", BATCH)):
        t = np.array(lat[klass])
        print(f"{name:>12}: ttft p50={np.percentile(t, 50) * 1e3:7.1f}ms "
              f"p95={np.percentile(t, 95) * 1e3:7.1f}ms")
    print(f"{'engine':>12}: {eng.stats['e2e_tok_per_s']:.1f} tok/s, "
          f"preemptions={eng.stats['preemptions']} "
          f"offloaded_pages={eng.stats['offloaded_pages']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--sparsity", type=float, default=0.9)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine lanes (default: --batch)")
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch")
    ap.add_argument("--slab-k", type=int, default=8,
                    help="decode steps per jitted slab (1 = per-token)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests "
                         "through the radix-tree prefix cache")
    ap.add_argument("--mixed", action="store_true",
                    help="stall-free mixed batching: fuse chunked "
                         "prefill into the decode step under the "
                         "prefill token budget")
    ap.add_argument("--frontdoor", action="store_true",
                    help="serve the packed model through the asyncio "
                         "front door (SLA classes + preemption with "
                         "host KV offload) and print per-class TTFT")
    ap.add_argument("--seal", default=None, metavar="DIR",
                    help="seal the packed weights into DIR as a "
                         "validated artifact (checksums + config "
                         "fingerprint + golden canaries), then exit")
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="serve from a sealed artifact instead of "
                         "packing fresh — fully validated (canaries "
                         "replayed) before serving; corrupt exits 2")
    ap.add_argument("--validate-only", action="store_true",
                    help="with --artifact: verify and exit")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    masks = {}
    for path in registry.sparse_paths(cfg):
        w = sm.get_path(params, path)
        bi, bo = sm.block_dims_for(cfg.blast, path)
        pspec = dataclasses.replace(cfg.blast, b_in=bi, b_out=bo,
                                    s_init=args.sparsity,
                                    s_max=args.sparsity)
        fn = lambda wi: initial_mask(pspec, wi)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        masks[path] = fn(w)

    if args.seal:
        from repro.serving import artifact as art
        packed = export.pack_params(cfg, params, masks,
                                    dtype=jnp.float32)
        manifest = art.seal(cfg, packed, args.seal)
        print(f"sealed {args.seal}: {len(manifest['checksums'])} "
              f"arrays, {len(manifest['canaries'])} canaries, "
              f"fingerprint {manifest['fingerprint'][:12]}…")
        return

    art_params = None
    if args.artifact:
        from repro.serving import artifact as art
        try:
            art_params, manifest = art.load(args.artifact, cfg,
                                            run_canaries=True)
        except art.ArtifactError as e:
            print(f"artifact INVALID ({type(e).__name__}): {e}")
            raise SystemExit(2)
        print(f"artifact OK: {len(manifest['checksums'])} arrays, "
              f"{len(manifest.get('canaries', []))} canaries replayed")
        if args.validate_only:
            return

    if args.frontdoor:
        if not registry.supports_prefill_chunk(cfg):
            raise SystemExit(
                f"--frontdoor needs an engine-servable family; "
                f"{cfg.family!r} is not")
        packed = (art_params if art_params is not None else
                  export.pack_params(cfg, params, masks,
                                     dtype=jnp.float32))
        frontdoor(cfg, packed, args)
        return

    rng = np.random.default_rng(0)
    use_engine = registry.supports_prefill_chunk(cfg)
    if use_engine:
        lens = (rng.integers(4, 9, size=args.batch) if args.ragged
                else [8] * args.batch)
        prompts = [rng.integers(0, cfg.vocab_size, size=(int(p),))
                   .astype(np.int32) for p in lens]
        def run(p):
            return engine.generate(cfg, p, prompts,
                                   max_new_tokens=args.new_tokens,
                                   max_batch=args.max_batch or args.batch,
                                   slab_k=args.slab_k,
                                   prefix_cache=args.prefix_cache,
                                   mixed=args.mixed)
    else:
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, 8)), jnp.int32)
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, 16, cfg.d_model)) * 0.02,
                jnp.float32)
        def run(p):
            return serve_loop.generate(cfg, p, prompts,
                                       max_new_tokens=args.new_tokens,
                                       **kw)

    if art_params is not None:
        t2, s2 = run(art_params)
        mp = export.memory_report(cfg, art_params)
        print(f"artifact: {s2['tok_per_s']:.1f} tok/s, "
              f"{mp['bytes']:,} B (validated weights)")
        return
    dense = export.prune_params(cfg, params, {}, dtype=jnp.float32)
    t1, s1 = run(dense)
    packed = export.pack_params(cfg, params, masks, dtype=jnp.float32)
    t2, s2 = run(packed)
    md = export.memory_report(cfg, dense)
    mp = export.memory_report(cfg, packed)
    print(f"dense : {s1['tok_per_s']:.1f} tok/s, {md['bytes']:,} B")
    print(f"packed: {s2['tok_per_s']:.1f} tok/s, {mp['bytes']:,} B "
          f"({md['bytes'] / mp['bytes']:.2f}x smaller at "
          f"{args.sparsity:.0%} sparsity)")


if __name__ == "__main__":
    main()
