"""End-to-end pretraining driver (paper §5.3): train an LM with BLaST on
the synthetic corpus, with checkpoint/restart fault tolerance — kill the
process mid-run and re-launch: it resumes from the last checkpoint.

Defaults are CPU-friendly; flags scale up to the paper's GPT2-XL
(--arch gpt2-xl --full).

    PYTHONPATH=src python examples/pretrain_blast.py [--steps 150]
"""
import argparse
import dataclasses

from repro.configs.base import reduced
from repro.configs.paper_models import GPT2_SMALL, GPT2_XL, LLAMA32_1B
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.training import train_loop

ARCHS = {"gpt2-small": GPT2_SMALL, "gpt2-xl": GPT2_XL,
         "llama3.2-1b": LLAMA32_1B}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small", choices=ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real accelerators)")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--s-max", type=float, default=0.8)
    ap.add_argument("--ckpt-dir", default="ckpts/pretrain_blast")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if not args.full:
        cfg = reduced(cfg, d_model=128, d_ff=512, num_layers=4,
                      vocab_size=512, num_heads=4, num_kv_heads=4,
                      head_dim=32)
    cfg = dataclasses.replace(cfg, blast=dataclasses.replace(
        cfg.blast, s_max=args.s_max, total_steps=args.steps,
        step_size=10, dense_last=2))

    source = SyntheticLM(cfg.vocab_size, seq_len=128, global_batch=16,
                         seed=0)
    opt = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=10,
                            total_steps=args.steps)
    loop = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=25, log_every=10)
    state, hist = train_loop.train(cfg, opt, source, loop)
    print(f"final: loss {hist[-1]['loss']:.4f} "
          f"sparsity {hist[-1]['sparsity']:.3f}")


if __name__ == "__main__":
    main()
