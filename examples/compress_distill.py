"""Post-training compression with knowledge distillation (paper §5.2):

1. pretrain a DENSE teacher;
2. initialise a BLaST student from the teacher's weights;
3. sparsify to 90% while training with alpha*CE + beta*KL against the
   teacher's logits;
4. report the perplexity gap and the packed memory reduction.

    PYTHONPATH=src python examples/compress_distill.py
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.distill import cross_entropy
from repro.core.prune_grow import BlastSpec
from repro.data.pipeline import SyntheticLM
from repro.models import registry
from repro.optim import adamw
from repro.serving import export
from repro.training import step as ts, train_loop


def make_cfg(blast_on):
    return ModelConfig(
        name="distill", family="dense", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=256,
        vocab_size=256, mlp_kind="glu", mlp_act="silu",
        norm_kind="rmsnorm", remat=False, compute_dtype="float32",
        blast=BlastSpec(enabled=blast_on, b_in=16, b_out=16, s_max=0.9,
                        total_steps=80, step_size=10, dense_last=1))


def ppl(cfg, state, src):
    losses = []
    for i in range(3):
        b = src.batch(50_000 + i)
        logits, _ = registry.forward(cfg, state.params,
                                     jnp.asarray(b["tokens"]),
                                     masks=state.masks or None)
        losses.append(float(cross_entropy(
            logits, jnp.asarray(b["labels"]))))
    return math.exp(np.mean(losses))


src = SyntheticLM(256, seq_len=64, global_batch=16, seed=0)

print("== 1. dense teacher ==")
tcfg = make_cfg(False)
opt = adamw.AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=120)
loop = train_loop.TrainLoopConfig(total_steps=120, log_every=40)
teacher, _ = train_loop.train(tcfg, opt, src, loop)
print(f"teacher ppl: {ppl(tcfg, teacher, src):.2f}")

print("== 2-3. BLaST student from teacher weights, CE+KL ==")
scfg = make_cfg(True)
student = ts.init_state(scfg, jax.random.PRNGKey(1))
student = dataclasses.replace(      # copy: train step donates buffers
    student, params=jax.tree_util.tree_map(jnp.copy, teacher.params))
opt2 = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=80)
loop2 = train_loop.TrainLoopConfig(total_steps=80, log_every=20)
student, hist = train_loop.train(
    scfg, opt2, src, loop2, state=student,
    teacher_params=teacher.params, teacher_cfg=tcfg, kd_beta=1.0)

print("== 4. report ==")
print(f"student ppl: {ppl(scfg, student, src):.2f} "
      f"(sparsity {hist[-1]['sparsity']:.2f})")
packed = export.pack_params(scfg, student.params, student.masks)
dense_b = export.memory_report(tcfg, teacher.params)["bytes"]
packed_b = export.memory_report(scfg, packed)["bytes"]
print(f"weights: {dense_b} B dense -> {packed_b} B packed "
      f"({dense_b / packed_b:.2f}x)")
