"""Quickstart: BLaST in ~60 lines.

Builds a small Llama-style LM, pretrains it WHILE the blocked
prune-and-grow sparsifier ramps the MLPs to 80% block sparsity, then
exports packed BCSC weights and serves a batch of prompts.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.prune_grow import BlastSpec
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.serving import export, serve_loop
from repro.training import train_loop

STEPS = 80

cfg = ModelConfig(
    name="quickstart-llama", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=256, mlp_kind="glu", mlp_act="silu",
    norm_kind="rmsnorm", remat=False, compute_dtype="float32",
    # the paper's technique: 80% block sparsity, 16x16 blocks,
    # refresh every 10 steps, keep the last MLP dense (paper §5.4.4)
    blast=BlastSpec(enabled=True, b_in=16, b_out=16, s_max=0.8,
                    total_steps=STEPS, step_size=10, dense_last=1),
)

source = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=16, seed=0)
opt = adamw.AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=STEPS)
loop = train_loop.TrainLoopConfig(total_steps=STEPS, log_every=20)

print("== pretraining with blocked prune-and-grow ==")
state, history = train_loop.train(cfg, opt, source, loop)
print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}, "
      f"MLP sparsity {history[-1]['sparsity']:.2f}")

print("== export: prune + pack to balanced BCSC ==")
pruned = export.prune_params(cfg, state.params, state.masks)
packed = export.pack_params(cfg, state.params, state.masks)
print("dense-layout bytes:", export.memory_report(cfg, pruned)["bytes"])
print("packed bytes:      ", export.memory_report(cfg, packed)["bytes"])

print("== serving (packed BSpMM path) ==")
prompts = jnp.asarray(source.batch(999)["tokens"][:4, :8])
tokens, stats = serve_loop.generate(cfg, packed, prompts,
                                    max_new_tokens=16)
print(f"{stats['tok_per_s']:.1f} tok/s")
print(tokens[:, 8:])
